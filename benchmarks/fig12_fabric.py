"""Fig. 12 (new): cross-host serving fabric -- fault injection and
elastic pods over a real message transport.

The fabric claim, measured end to end: a router speaking to pods over
framed messages (not method calls) keeps serving through a pod death.
The headline harness runs one worker PROCESS per pod over stdin/stdout
pipes and ``kill -9``'s one mid-decode: the router's heartbeat/EOF
detection evicts the dead pod from the placement ring, its in-flight
requests are re-routed to survivors exactly once each (requests with
committed tokens resume via the preemption machinery's suffix
re-prefill), and the elastic fleet heals back to its floor.

Acceptance bars (they FAIL the run, not just fields in the artifact):

  * **zero lost requests**: every submitted request reaches ``done``
    despite the kill, and the fleet-wide span-closure check (pooled
    across per-process span files) confirms every routed rid reached a
    terminal span somewhere;
  * **bitwise token parity**: every re-routed request's tokens are
    identical to an unkilled run of the same trace -- failover is
    invisible in the output;
  * **the fault was real**: the victim had in-flight mid-decode work at
    kill time, exactly one eviction fired, and >= 1 request re-routed;
  * **elastic fleet**: under a token-backlog trigger the fleet scales
    above its initial size, and after a sustained idle streak drains +
    retires back down -- with the outstanding-token ledger settling to
    exactly zero.

Metrics are written to ``BENCH_fabric.json`` (``--smoke`` writes
``BENCH_fabric_smoke.json`` so CI never clobbers the full artifact).
"""

from __future__ import annotations

import json
import os
import signal
import tempfile
from pathlib import Path

import numpy as np

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""

POD_KWARGS = dict(replicas=1, n_slots=2, max_len=96)
MAX_TICKS = 20_000


def _trace(n):
    from repro.orchestrator import GenRequest
    rng = np.random.default_rng(0)
    return [GenRequest(
        rid=i,
        prompt=rng.integers(0, 256, int(rng.integers(4, 16))),
        max_new_tokens=int(rng.integers(6, 20)),
        arrival=i // 6) for i in range(n)]


def _fresh_root(tag):
    from repro.core.runtime import Runtime
    rt = Runtime(tempfile.mkdtemp(prefix=f"stevedore-fig12-{tag}-"))
    rt.build(IMAGEFILE, tag="bench")
    return rt


def _router(rt, spawn, **kw):
    from repro.orchestrator import FabricRouter
    return FabricRouter(spawn, runtime=rt, **kw)


def _kill_mid_decode(router, kill):
    """Step until some member holds a request that has committed tokens
    but not finished (mid-decode), then ``kill`` that member. Returns the
    victim's pod_id and its in-flight count at kill time."""
    while router.busy and router.tick < MAX_TICKS:
        victim = next(
            (m for m in router.members.values()
             if any(r.tokens and len(r.tokens) < r.max_new_tokens
                    for r in m.assigned.values())),
            None)
        if victim is not None:
            inflight = len(victim.assigned)
            kill(victim)
            return victim.pod_id, inflight
        router.step()
    raise AssertionError("no member was ever mid-decode; trace too small")


def _drain(router):
    while router.busy and router.tick < MAX_TICKS:
        router.step()
    assert not router.busy, "fabric run did not converge"
    return router.completed


def _check_zero_lost(reqs, done, tag):
    assert len(done) == len(reqs), \
        f"{tag}: {len(reqs) - len(done)} request(s) lost"
    assert all(r.state == "done" for r in reqs), \
        f"{tag}: non-terminal states {sorted({r.state for r in reqs})}"


def _parity(base_tokens, reqs, tag):
    mismatch = [r.rid for r in reqs if base_tokens[r.rid] != list(r.tokens)]
    assert not mismatch, \
        f"{tag}: token mismatch vs unkilled run for rids {mismatch}"


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    from repro.orchestrator import (loopback_spawner, proc_spawner,
                                    load_fleet_spans)
    from repro.orchestrator.obs import validate_fleet_closure, \
        validate_span_log

    n = 12 if smoke else 24

    # A) unkilled loopback baseline: the token oracle for every later arm
    rt = _fresh_root("base")
    spawn = loopback_spawner(rt, rt.pull("bench"), pod_kwargs=POD_KWARGS)
    router = _router(rt, spawn, pods=2, min_pods=2)
    reqs = _trace(n)
    router.submit(reqs)
    base_done = _drain(router)
    _check_zero_lost(reqs, base_done, "baseline")
    base_tokens = {r.rid: list(r.tokens) for r in reqs}
    base_ticks = router.tick
    router.close()

    # B) loopback fault injection: deterministic kill mid-decode
    rt = _fresh_root("loop")
    spawn = loopback_spawner(rt, rt.pull("bench"), pod_kwargs=POD_KWARGS)
    router = _router(rt, spawn, pods=2, min_pods=2)
    reqs = _trace(n)
    router.submit(reqs)
    victim, loop_inflight = _kill_mid_decode(
        router, lambda m: m.transport.kill())
    loop_done = _drain(router)
    _check_zero_lost(reqs, loop_done, "loopback-kill")
    _parity(base_tokens, reqs, "loopback-kill")
    loop_fabric = router.status()["fabric"]
    assert loop_fabric["evictions"] == 1, loop_fabric
    assert loop_fabric["reroutes"] >= 1, \
        "victim had in-flight work but nothing re-routed"
    assert router.outstanding_total == 0, "ledger did not settle to zero"
    loop_buffers = router.trace_buffers()
    validate_span_log(loop_buffers)
    loop_closure = validate_fleet_closure(loop_buffers)
    rerouted = [r for r in reqs if r.reroutes]
    assert len(rerouted) == loop_closure["rerouted"]
    router.close()

    # C) loopback elastic: token-backlog scale-up, idle-streak scale-down
    rt = _fresh_root("elastic")
    spawn = loopback_spawner(rt, rt.pull("bench"), pod_kwargs=POD_KWARGS)
    router = _router(rt, spawn, pods=1, min_pods=1, max_pods=3,
                     scale_up_tokens=40, scale_idle_ticks=6)
    reqs = _trace(n)
    router.submit(reqs)
    peak = 1
    while router.busy and router.tick < MAX_TICKS:
        router.step()
        peak = max(peak, len(router.members))
    _check_zero_lost(reqs, router.completed, "elastic")
    _parity(base_tokens, reqs, "elastic")
    # idle past the streak so drains + retires fire
    for _ in range(40):
        router.step()
    elastic_fabric = router.status()["fabric"]
    assert peak > 1, "backlog never triggered a scale-up"
    assert elastic_fabric["retired"] >= 1, \
        "idle fleet never drained + retired a pod"
    assert len(router.members) >= 1
    assert router.outstanding_total == 0
    router.close()

    # D) the headline: process-per-pod harness, real kill -9 mid-decode
    rt = _fresh_root("proc")
    spawn = proc_spawner(rt.root, imagefile=IMAGEFILE,
                         pod_kwargs=POD_KWARGS)
    router = _router(rt, spawn, pods=2, min_pods=2, wall_clock=True,
                     heartbeat_every=2)
    reqs = _trace(n)
    router.submit(reqs)
    proc_victim, proc_inflight = _kill_mid_decode(
        router, lambda m: os.kill(m.transport.pid, signal.SIGKILL))
    proc_done = _drain(router)
    _check_zero_lost(reqs, proc_done, "proc-kill")
    _parity(base_tokens, reqs, "proc-kill")
    proc_fabric = router.status()["fabric"]
    assert proc_fabric["evictions"] == 1, proc_fabric
    assert proc_fabric["reroutes"] >= 1
    assert router.outstanding_total == 0
    router.close()
    # per-process span files, pooled: the cross-host closure check
    proc_buffers = load_fleet_spans(rt.root, fleet=router.fleet)
    validate_span_log(proc_buffers)
    proc_closure = validate_fleet_closure(proc_buffers)
    assert proc_closure["rerouted"] >= 1

    payload = {
        "arch": "llama3.2-3b-smoke",
        "smoke": smoke,
        "requests": n,
        "pod_kwargs": POD_KWARGS,
        "baseline_ticks": base_ticks,
        "loopback_kill": {
            "victim": victim,
            "inflight_at_kill": loop_inflight,
            "evictions": loop_fabric["evictions"],
            "reroutes": loop_fabric["reroutes"],
            "rerouted_requests": sorted(r.rid for r in rerouted),
            "closure": loop_closure,
            "token_parity": True,
        },
        "elastic": {
            "peak_pods": peak,
            "spawned": elastic_fabric["spawned"],
            "retired": elastic_fabric["retired"],
            "token_parity": True,
        },
        "proc_kill": {
            "victim": proc_victim,
            "inflight_at_kill": proc_inflight,
            "evictions": proc_fabric["evictions"],
            "reroutes": proc_fabric["reroutes"],
            "closure": proc_closure,
            "token_parity": True,
        },
        "requests_lost": 0,
    }
    out = ("BENCH_fabric_smoke.json" if smoke else "BENCH_fabric.json")
    Path(out).write_text(json.dumps(payload, indent=2))

    return [
        ("fig12/requests", float(n), "staggered trace, every arm"),
        ("fig12/loopback_reroutes", float(loop_fabric["reroutes"]),
         f"in-flight moved off {victim} after deterministic kill"),
        ("fig12/proc_reroutes", float(proc_fabric["reroutes"]),
         f"in-flight moved off {proc_victim} after kill -9"),
        ("fig12/requests_lost", 0.0,
         "fleet span closure: every routed rid terminal"),
        ("fig12/token_parity", 1.0,
         "rerouted tokens bitwise == unkilled run (all arms)"),
        ("fig12/elastic_peak_pods", float(peak),
         "token-backlog scale-up above the 1-pod floor"),
        ("fig12/elastic_retired", float(elastic_fabric["retired"]),
         "idle-streak drain + retire back down"),
    ]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace (CI)")
    a = ap.parse_args()
    for name, value, derived in run(smoke=a.smoke):
        print(f"{name},{value:.3f},{derived}")
