"""Fig. 4 analog: the import problem -- cold vs cached per-host startup.

Paper: at 24..96 ranks the native Python run pays minutes of per-process
module imports; the container (one big image file per node) does not.

Here the per-host startup cost is trace+lower+compile of the train step.
Cold = full build. Warm = CompileCache L1 hit (deserialize one artifact).
The projected cluster column multiplies the per-host saving by host count
(every host performs the same redundant build; the cache is shared like the
paper's per-node image mount).
"""

from __future__ import annotations

import tempfile
import time

from repro.core.compile_cache import CompileCache
from repro.core.container import Container
from repro.core.image import ImageBuilder

ARCH = "llama3.2-3b-smoke"
HOSTS = (4, 64, 1000)


def build_image():
    return (ImageBuilder.from_scratch()
            .arch(ARCH)
            .shape("train_4k", seq_len=64, global_batch=4)
            .mesh("local")
            .collectives("generic")
            .build())


def run() -> list[tuple[str, float, str]]:
    tmp = tempfile.mkdtemp()
    cache = CompileCache(f"{tmp}/cc")
    image = build_image()

    c1 = Container(image, overlay_root=tmp, compile_cache=cache)
    t0 = time.perf_counter()
    c1.compile_step("train")
    cold = time.perf_counter() - t0

    c2 = Container(image, overlay_root=tmp, compile_cache=cache)
    t0 = time.perf_counter()
    c2.compile_step("train")
    warm = time.perf_counter() - t0
    level = cache.stats.last_level

    rows = [
        ("fig4/startup_cold_us", cold * 1e6, "trace+lower+compile"),
        (f"fig4/startup_warm_us", warm * 1e6, f"cache={level}"),
        ("fig4/speedup_x", cold / max(warm, 1e-9), ""),
    ]
    for n in HOSTS:
        saved = (cold - warm) * n
        rows.append((f"fig4/cluster_{n}hosts_saved_s", saved * 1e6 / 1e6,
                     "aggregate redundant build time avoided"))
    return rows


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.3f},{extra}")
