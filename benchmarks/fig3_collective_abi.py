"""Fig. 3 analog: generic vs host collective ABI across the pod boundary.

Paper: (a) native, (b) Shifter + Cray MPI, (c) Shifter + container MPICH on
a Cray XC30 at 24..192 ranks; (c) collapses once the job crosses a node.

Here, from the dry-run artifacts (same lower+compile machinery, offline):
per mesh {pod 256, multipod 512} and ABI {generic, host}, the roofline
collective term + wire bytes of the deepseek-67b train step. ``generic``
(flat fp32 all-reduce, replicated optimizer) degrades crossing the pod
boundary; ``host`` (ZeRO-1 reduce-scatter/all-gather + bf16 wire +
hierarchical reduction) is the Cray-MPI analog.

Reads cached artifacts if present; lowers them (minutes) if not.
"""

from __future__ import annotations

import json
from pathlib import Path

ARCH = "deepseek-67b"
SHAPE = "train_4k"
DIR = Path("results/dryrun")

VARIANTS = [
    # (tag-suffix, abi, settings)
    ("", "generic", {"remat": "dots"}),
    ("host", "host", {"remat": "dots", "fsdp": True}),
]


def _artifact(mesh: str, tag: str) -> Path:
    suffix = f"-{tag}" if tag else ""
    return DIR / f"{ARCH}__{SHAPE}__{mesh}{suffix}.json"


def ensure(mesh: str, tag: str, abi: str, settings: dict) -> dict:
    p = _artifact(mesh, tag)
    if not p.exists():
        # subprocess: the dry-run needs 512 host devices (XLA_FLAGS is set
        # before jax import inside dryrun.py; it cannot be set here)
        import subprocess, sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", ARCH, "--shape", SHAPE, "--mesh", mesh,
               "--collectives", abi, "--settings", json.dumps(settings),
               "--out", str(DIR)]
        if tag:
            cmd += ["--tag", tag]
        subprocess.run(cmd, check=True, capture_output=True, text=True,
                       env={**__import__("os").environ, "PYTHONPATH": "src"})
    return json.loads(p.read_text())


def run() -> list[tuple[str, float, str]]:
    rows = []
    for mesh in ("pod", "multipod"):
        for tag, abi, settings in VARIANTS:
            try:
                rec = ensure(mesh, tag, abi, settings)
            except Exception as e:  # pragma: no cover
                rows.append((f"fig3/{mesh}/{abi}/error", 0.0, str(e)[:80]))
                continue
            if rec.get("status") != "ok":
                continue
            rl = rec["roofline"]
            rows.append((f"fig3/{mesh}/{abi}/collective_s",
                         rl["collective_s"] * 1e6,
                         f"wire_bytes/dev={rl['wire_bytes_per_device']:.3e}"))
            rows.append((f"fig3/{mesh}/{abi}/step_bound_s",
                         max(rl["compute_s"], rl["memory_s"],
                             rl["collective_s"]) * 1e6,
                         f"dominant={rl['dominant']}"))

    # the cleanest pod-boundary story: llama4's EP cell. With fixed global
    # batch, healthy scaling keeps collective/compute FLAT across the pod
    # boundary; the pre-fix dispatch showed ratio 26 (the Fig.3 collapse,
    # EXPERIMENTS.md §Perf L1).
    for mesh in ("pod", "multipod"):
        p = DIR / f"llama4-scout-17b-a16e__train_4k__{mesh}.json"
        if p.exists():
            rec = json.loads(p.read_text())
            if rec.get("status") == "ok":
                rl = rec["roofline"]
                ratio = rl["collective_s"] / max(rl["compute_s"], 1e-12)
                rows.append((f"fig3/llama4/{mesh}/coll_over_compute", ratio,
                             f"collective_s={rl['collective_s']:.2f}"))
    rows.append(("fig3/llama4/multipod_prefix/coll_over_compute", 26.2,
                 "pre-fix EP dispatch (axis-order reshard): the collapse; "
                 "see EXPERIMENTS.md §Perf L1"))
    return rows


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.1f},{extra}")
