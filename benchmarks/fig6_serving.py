"""Fig. 6 (new): continuous batching vs the static-batch serving baseline.

The orchestrator claim, measured: at EQUAL batch capacity (one replica of
``SLOTS`` KV slots vs a static batch of ``SLOTS``), a staggered
variable-length request trace decodes >= 1.5x faster under continuous
batching, because finished requests release their slot the same tick
instead of idling until the longest request in their wave completes.

Metrics (also written to ``BENCH_serving.json``):
  * decode throughput (useful tokens / decode seconds) for both modes;
  * decode ticks (the hardware-independent view of the same ratio);
  * p50/p99 request latency in ticks for the continuous mode.

Run standalone (``python -m benchmarks.fig6_serving``) or via
``python -m benchmarks.run --only fig6_serving``.
"""

from __future__ import annotations

import io
import json
import tempfile
from contextlib import redirect_stdout
from pathlib import Path
from types import SimpleNamespace

import numpy as np

ARCH = "llama3.2-3b"
SLOTS = 8           # equal capacity on both sides
REQUESTS = 32
REPS = 3            # best-of-N timing reps per mode (noisy shared CPUs)
PROMPT = 24
GEN = 64            # static decodes GEN steps for every wave member
MAX_LEN = 104

# big enough that a decode tick is compute-dominated (a tiny smoke model
# would measure host dispatch overhead, not serving policy)
IMAGEFILE = f"""
FROM scratch
ARCH {ARCH} n_layers=4 d_model=256 n_heads=8 n_kv_heads=4 head_dim=32 d_ff=768 vocab_size=8192
SHAPE decode_32k seq_len={MAX_LEN} global_batch={SLOTS}
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""


def _trace(rng, vocab):
    """Staggered arrivals with the SAME heavy-tailed budgets the static
    driver replays (launch.serve._tail_budgets): most requests short, a few
    run the full budget -- the shape that makes a static wave idle most of
    its slots on its longest member."""
    from repro.launch.serve import _tail_budgets
    from repro.orchestrator import GenRequest
    budgets = _tail_budgets(GEN, REQUESTS)
    return [GenRequest(rid=i,
                       prompt=rng.integers(0, vocab, PROMPT),
                       max_new_tokens=budgets[i],
                       arrival=i // 8)
            for i in range(REQUESTS)]


def run() -> list[tuple[str, float, str]]:
    from repro.core.runtime import Runtime
    from repro.launch.serve import serve_static
    from repro.orchestrator import ContinuousScheduler, Pod

    rt = Runtime(tempfile.mkdtemp(prefix="stevedore-fig6-"))
    image = rt.build(IMAGEFILE, tag="bench")
    rng = np.random.default_rng(0)

    # -- continuous: one replica, SLOTS slots --------------------------------
    pod = Pod(rt, "bench", replicas=1, n_slots=SLOTS, max_len=MAX_LEN)
    eng = pod.engines[0]
    cfg = eng.container.arch
    # warm the decode + prefill executables out of the measurement
    warm = ContinuousScheduler(pod, fairness_cap=4)
    warm.submit(_trace(rng, cfg.vocab_size)[:SLOTS])
    warm.run()
    # best-of-REPS reps (min decode time): continuous makes ~8x more
    # dispatches than the scanned static loop, so background load noise
    # hits it harder; min-time is the standard noisy-timer estimator
    best = None
    for _ in range(REPS):
        reqs = _trace(rng, cfg.vocab_size)
        eng.decode_s = eng.prefill_s = 0.0
        t0 = eng.decode_ticks
        # fresh span log per rep: the tick clock restarts with the
        # scheduler, so every rep records the identical spans and the
        # last rep's log stands for all of them
        pod.trace.clear()
        # fresh scheduler per rep: tick restarts at 0, stagger honored
        sched = ContinuousScheduler(pod, fairness_cap=4)
        sched.submit(reqs)
        sched.run()
        if best is None or eng.decode_s < best[0]:
            best = (eng.decode_s, eng.decode_ticks - t0, reqs)
    cont_s, cont_ticks, reqs = best
    # TTFT / inter-token latency decomposition from the span log (ticks
    # are identical across reps -- only wall time varies)
    from repro.orchestrator.obs import decomposition
    decomp = decomposition([pod.trace])
    cont_tokens = sum(len(r.tokens) for r in reqs)
    # latency from arrival (the stagger is offered load, not queueing
    # delay); nearest-rank percentiles shared with serve.py and fig8
    from repro.orchestrator.telemetry import nearest_rank, request_latencies
    lat = request_latencies(reqs)
    p50 = nearest_rank(lat, 50)
    p99 = nearest_rank(lat, 99)

    # -- static baseline: the actual launch/serve.py --mode static driver,
    # best-of-REPS (first call warms prefill/generate through the cache) ----
    static_args = SimpleNamespace(slots=SLOTS, prompt_len=PROMPT, gen=GEN,
                                  requests=REQUESTS, seed=0, platform=None)
    best_static = None
    for _ in range(REPS + 1):               # +1: first rep is the warm-up
        with redirect_stdout(io.StringIO()):
            res = serve_static(rt, "bench", static_args)
        if best_static is None or res["decode_s"] < best_static["decode_s"]:
            best_static = res
    static_s = best_static["decode_s"]
    static_tokens = best_static["tokens"]
    static_ticks = best_static["decode_ticks"]

    cont_tps = cont_tokens / max(cont_s, 1e-9)
    stat_tps = static_tokens / max(static_s, 1e-9)
    speedup = cont_tps / max(stat_tps, 1e-9)
    tick_ratio = static_ticks / max(cont_ticks, 1)

    payload = {
        "arch": ARCH, "slots": SLOTS, "requests": REQUESTS,
        "prompt_len": PROMPT, "gen_max": GEN,
        "continuous": {"tokens": cont_tokens, "decode_s": cont_s,
                       "decode_ticks": cont_ticks, "tok_per_s": cont_tps,
                       "p50_latency_ticks": p50, "p99_latency_ticks": p99,
                       "tokens_wasted": eng.tokens_wasted,
                       **decomp},
        "static": {"tokens": static_tokens, "decode_s": static_s,
                   "decode_ticks": static_ticks, "tok_per_s": stat_tps},
        "decode_speedup_x": speedup,
        "tick_ratio_x": tick_ratio,
    }
    Path("BENCH_serving.json").write_text(json.dumps(payload, indent=2))

    return [
        ("fig6/continuous_decode_tok_per_s", cont_tps,
         f"{cont_tokens} tok / {cont_ticks} ticks"),
        ("fig6/static_decode_tok_per_s", stat_tps,
         f"{static_tokens} useful tok / {static_ticks} ticks"),
        ("fig6/decode_speedup_x", speedup, "continuous vs static, equal capacity"),
        ("fig6/tick_ratio_x", tick_ratio, "static ticks / continuous ticks"),
        ("fig6/p50_latency_ticks", float(p50), ""),
        ("fig6/p99_latency_ticks", float(p99), ""),
        ("fig6/ttft_p50_ticks", float(decomp["ttft_p50_ticks"]),
         "time-to-first-token, from spans"),
        ("fig6/ttft_p99_ticks", float(decomp["ttft_p99_ticks"]), ""),
        ("fig6/itl_p50_ticks", float(decomp["itl_p50_ticks"]),
         "inter-token latency, ticks/token"),
        ("fig6/itl_p99_ticks", float(decomp["itl_p99_ticks"]), ""),
    ]


if __name__ == "__main__":
    for name, value, derived in run():
        print(f"{name},{value:.3f},{derived}")
