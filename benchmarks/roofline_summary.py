"""Roofline summary rows from the dry-run artifact directory (§Roofline
feed: one row per (arch, shape, mesh) with the three terms + dominant)."""

from __future__ import annotations

import json
from pathlib import Path

DIR = Path("results/dryrun")


def run() -> list[tuple[str, float, str]]:
    rows = []
    for p in sorted(DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok":
            continue
        rl = r["roofline"]
        tag = p.stem
        rows.append((
            f"roofline/{tag}/bound_us",
            max(rl["compute_s"], rl["memory_s"], rl["collective_s"]) * 1e6,
            f"dom={rl['dominant']};useful={rl['useful_flops_fraction']:.3f};"
            f"frac={rl['roofline_fraction']:.4f}",
        ))
    return rows


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.1f},{extra}")
