"""Fig. 9 (new): copy-on-write prefix page cache on a shared-system-prompt
trace.

The paper's layer-sharing claim applied to serving: every request carries
the same leading system prompt, so its KV pages -- like an image's base
layers -- are immutable shared state. With ``--prefix-cache`` the paged
engine prefills the shared block ONCE, promotes its pages into the
digest-keyed prefix index, and every later request maps them copy-on-write
and prefills only its private suffix.

Measured at EQUAL KV HBM (same page pool) against ``--paged`` without the
cache, on the same trace:

  * **prefill-token reduction**: total positions actually computed by
    prefill drops by the shared block per hit -- the >= 1.3x acceptance
    bar;
  * **admitted capacity**: hit requests reserve only their suffix pages,
    so the same pool admits more concurrent requests (peak concurrent
    admitted, the fig7 metric);
  * **exactness**: request tokens are bitwise identical cache-on vs
    cache-off (suffix prefill with offset positions changes nothing
    observable).

Metrics are written to ``BENCH_prefix.json`` (``--smoke`` writes
``BENCH_prefix_smoke.json`` so CI never clobbers the full artifact).
"""

from __future__ import annotations

import json
import tempfile
from pathlib import Path

import numpy as np

PAGE_SIZE = 16
SHARED = 48                 # system prompt: 3 whole pages
TAIL = 16                   # per-request private prompt (max)
GEN = 32
REQUESTS = 32
SLOTS = 16                  # host bookkeeping; pages are the budget
N_PAGES = 29                # tight pool: admission is pool-bound
SPAN = 192                  # per-request page-table ceiling

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""


def _trace(vocab, n, gen):
    """Shared-system-prompt trace with the fig6/fig7 heavy-tailed budgets,
    offered at tick 0 so pool pressure -- not arrival stagger -- limits
    concurrency. Regenerated per run (GenRequests are stateful)."""
    from repro.launch.serve import _tail_budgets
    from repro.orchestrator import GenRequest
    rng = np.random.default_rng(0)
    sys_prompt = rng.integers(0, vocab, SHARED)
    budgets = _tail_budgets(gen, n)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab, 4 + (i * 5) % (TAIL - 3))
        reqs.append(GenRequest(rid=i,
                               prompt=np.concatenate([sys_prompt, tail]),
                               max_new_tokens=budgets[i],
                               prefix_len=SHARED))
    return reqs


def _drive(pod, reqs, max_ticks=20_000):
    """Run to completion tracking peak concurrent admitted requests (the
    fig7 packing metric: post-admission residency before this tick's
    decode retires the short requests)."""
    from repro.orchestrator import ContinuousScheduler
    sched = ContinuousScheduler(pod, fairness_cap=32)
    sched.submit(reqs)
    peak = 0
    while sched.busy and sched.tick < max_ticks:
        pre = sum(len(e.active) for e in pod.engines)
        adm0 = len(sched.admission_order)
        sched.step()
        peak = max(peak, pre + len(sched.admission_order) - adm0)
    return peak


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    from repro.core.runtime import Runtime
    from repro.orchestrator import Pod

    n_requests = 10 if smoke else REQUESTS
    gen = 16 if smoke else GEN

    rt = Runtime(tempfile.mkdtemp(prefix="stevedore-fig9-"))
    rt.build(IMAGEFILE, tag="bench")

    runs = {}
    for cache in (False, True):
        pod = Pod(rt, "bench", replicas=1, n_slots=SLOTS, max_len=SPAN,
                  paged=True, page_size=PAGE_SIZE, n_pages=N_PAGES,
                  prefix_cache=cache)
        vocab = pod.engines[0].container.arch.vocab_size
        reqs = _trace(vocab, n_requests, gen)
        peak = _drive(pod, reqs)
        eng = pod.engines[0]
        eng.pool.check()            # allocator clean after the full trace
        assert all(r.state == "done" for r in reqs), "trace dropped work"
        from repro.orchestrator.obs import decomposition
        runs[cache] = {
            "peak_concurrent": peak,
            "prefill_positions": eng.prefill_positions,
            "prefix_hits": eng.prefix_hits,
            "prefix_tokens_saved": eng.prefix_tokens_saved,
            "peak_pages_in_use": eng.pool.peak_in_use,
            # TTFT/ITL from the pod's span log: the cache should shrink
            # TTFT (shorter prefill + faster admission under pool pressure)
            # while ITL stays decode-bound
            **decomposition([pod.trace]),
            "tokens": {r.rid: list(r.tokens) for r in reqs},
        }

    parity = runs[False]["tokens"] == runs[True]["tokens"]
    reduction = (runs[False]["prefill_positions"]
                 / max(runs[True]["prefill_positions"], 1))
    capacity_gain = (runs[True]["peak_concurrent"]
                     / max(runs[False]["peak_concurrent"], 1))
    # the acceptance bars FAIL the run (and the CI smoke step), they are
    # not just fields in the artifact nothing reads
    assert parity, "request tokens differ cache-on vs cache-off"
    assert reduction >= 1.3, \
        f"prefill-token reduction {reduction:.2f}x below the 1.3x bar"

    payload = {
        "arch": "llama3.2-3b-smoke",
        "smoke": smoke,
        "page_size": PAGE_SIZE,
        "pool_pages": N_PAGES - 1,
        "shared_prefix_tokens": SHARED,
        "requests": n_requests,
        "gen_max": gen,
        "cache_off": {k: v for k, v in runs[False].items() if k != "tokens"},
        "cache_on": {k: v for k, v in runs[True].items() if k != "tokens"},
        "prefill_token_reduction_x": reduction,
        "admitted_capacity_gain_x": capacity_gain,
        "token_parity_on_vs_off": parity,
    }
    out = "BENCH_prefix_smoke.json" if smoke else "BENCH_prefix.json"
    Path(out).write_text(json.dumps(payload, indent=2))

    return [
        ("fig9/prefill_positions_off",
         float(runs[False]["prefill_positions"]),
         f"{n_requests} reqs x (shared {SHARED} + tail)"),
        ("fig9/prefill_positions_on",
         float(runs[True]["prefill_positions"]),
         f"{runs[True]['prefix_hits']} hits skipped the shared pages"),
        ("fig9/prefill_token_reduction_x", reduction, ">= 1.3x bar"),
        ("fig9/peak_concurrent_off", float(runs[False]["peak_concurrent"]),
         f"{N_PAGES - 1} pages, full reservations"),
        ("fig9/peak_concurrent_on", float(runs[True]["peak_concurrent"]),
         "suffix-only reservations at equal KV HBM"),
        ("fig9/admitted_capacity_gain_x", capacity_gain,
         "cache-on vs cache-off, same pool"),
        ("fig9/token_parity_on_vs_off", float(parity),
         "bitwise-identical request tokens"),
        ("fig9/ttft_p99_ticks_off", float(runs[False]["ttft_p99_ticks"]),
         "time-to-first-token, full reservations"),
        ("fig9/ttft_p99_ticks_on", float(runs[True]["ttft_p99_ticks"]),
         "suffix-only reservations admit sooner"),
        ("fig9/itl_p50_ticks_on", float(runs[True]["itl_p50_ticks"]),
         "inter-token latency stays decode-bound"),
    ]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace (CI)")
    a = ap.parse_args()
    for name, value, derived in run(smoke=a.smoke):
        print(f"{name},{value:.3f},{derived}")
