"""Fig. 2 analog: container-vs-native performance parity on a workstation.

Paper: four FEniCS workloads x {Docker, rkt, native, VM} on a Xeon; result:
containers match native (<1%), VM pays ~15%.

Here: four workloads x {native, containerized}:
  native        = hand-built jax train/prefill/decode/io path, no framework
  containerized = identical workload built through Imagefile -> Registry ->
                  Container (the full runtime stack)
Both execute on the local platform; the claim under test is that the
container abstraction adds NO per-step overhead (it binds at trace time).
An interpret-mode "VM" analog exists in fig5 (kernels); here the VM column
is represented by the jit-disabled python path to mirror the paper's
"emulation tax" bar.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.image import ImageBuilder
from repro.core.container import Container
from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import params as P
from repro.models.transformer import Model
from repro.serve.serve_step import ServeStepBuilder
from repro.dist.mesh import make_platform_mesh
from repro.dist.sharding import ShardingRules
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import TrainStepBuilder
from repro.core.abi import make_abi

ARCH = "llama3.2-3b-smoke"
B, S = 4, 64
REPS = 30


def _time_once(fn):
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return (time.perf_counter() - t0) * 1e6         # us


def _interleaved(pairs: dict, reps: int = REPS) -> dict:
    """Measure {name: (fn_a, fn_b)} round-robin and return medians --
    interleaving cancels slow drift (other processes, thermal) that a
    sequential A-then-B measurement would attribute to B."""
    import statistics
    samples = {k: ([], []) for k in pairs}
    for k, (fa, fb) in pairs.items():               # warmup + compile
        jax.block_until_ready(fa())
        jax.block_until_ready(fb())
    for _ in range(reps):
        for k, (fa, fb) in pairs.items():
            samples[k][0].append(_time_once(fa))
            samples[k][1].append(_time_once(fb))
    return {k: (statistics.median(a), statistics.median(b))
            for k, (a, b) in samples.items()}


def _batch(cfg):
    d = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=S,
                               global_batch=B, seed=0))
    return {k: jnp.asarray(v) for k, v in d.batch(0).items()}


def native_runs():
    """Workloads built directly against the model/train/serve layers."""
    cfg = get_config(ARCH)
    mesh = make_platform_mesh("local")
    m = Model(cfg, tp=1)
    prm = P.materialize(m.param_defs(), jax.random.key(0))
    opt = adamw_init(prm)
    builder = TrainStepBuilder(model=m, mesh=mesh,
                               rules=ShardingRules.default(),
                               abi=make_abi("generic"), opt=OptConfig())
    train = jax.jit(builder.build())
    serve = ServeStepBuilder(m, mesh, ShardingRules.default())
    prefill = jax.jit(serve.build_prefill(cache_len=S + 8))
    decode = jax.jit(serve.build_decode())
    batch = _batch(cfg)
    _, cache = prefill(prm, batch["tokens"])
    tok = jnp.zeros((B, 1), jnp.int32)
    return {
        "train_step": lambda: train(prm, opt, batch)[2]["loss"],
        "prefill": lambda: prefill(prm, batch["tokens"])[0],
        "decode": lambda: decode(prm, cache, tok, jnp.int32(S))[0],
        "io_checkpoint": lambda: _io_workload(prm),
    }


def container_runs(tmpdir):
    cfg = get_config(ARCH)
    image = (ImageBuilder.from_scratch()
             .arch(ARCH)
             .shape("train_4k", seq_len=S, global_batch=B)
             .mesh("local")
             .precision(params="float32", compute="bfloat16")
             .collectives("generic")
             .build())
    c = Container(image, overlay_root=tmpdir)
    prm = c.init_params(0)
    opt = c.init_opt_state(prm)
    train = jax.jit(c.train_step_fn())
    prefill = jax.jit(c.prefill_fn(cache_len=S + 8))
    decode = jax.jit(c.decode_fn())
    batch = _batch(cfg)
    _, cache = prefill(prm, batch["tokens"])
    tok = jnp.zeros((B, 1), jnp.int32)
    return {
        "train_step": lambda: train(prm, opt, batch)[2]["loss"],
        "prefill": lambda: prefill(prm, batch["tokens"])[0],
        "decode": lambda: decode(prm, cache, tok, jnp.int32(S))[0],
        "io_checkpoint": lambda: _io_workload(prm, tmpdir),
    }


def _io_workload(prm, root=None):
    import tempfile
    d = root or tempfile.mkdtemp()
    store = CheckpointStore(f"{d}/io-bench")
    t0 = time.perf_counter()
    for i in range(3):
        store.save(i, prm, blocking=True)
        store.restore(prm, i)
    return (time.perf_counter() - t0) / 3 * 1e6


def run() -> list[tuple[str, float, str]]:
    import tempfile
    nat = native_runs()
    con = container_runs(tempfile.mkdtemp())
    pairs = {k: (nat[k], con[k]) for k in nat if k != "io_checkpoint"}
    med = _interleaved(pairs)
    rows = []
    for k, (a, b) in med.items():
        overhead = (b - a) / a * 100
        rows.append((f"fig2/{k}/native_us", a, ""))
        rows.append((f"fig2/{k}/container_us", b,
                     f"overhead={overhead:+.1f}%"))
    # io runs once per side (it is seconds-scale and disk-bound)
    a, b = nat["io_checkpoint"](), con["io_checkpoint"]()
    rows.append(("fig2/io_checkpoint/native_us", a, ""))
    rows.append(("fig2/io_checkpoint/container_us", b,
                 f"overhead={(b-a)/a*100:+.1f}%"))
    return rows


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.1f},{extra}")
