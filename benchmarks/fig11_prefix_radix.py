"""Fig. 11 (new): radix-tree prefix registry with tiered page storage on a
multi-tenant trace.

Fig. 9 showed the container-layer trick for ONE shared system prompt: a
flat digest-keyed index, one entry per whole declared prefix. Real fleets
serve M tenants, each with K few-shot prompt VARIANTS stacked on the same
system prompt -- a flat index stores every variant disjointly and a pool
under pressure evicts whole prefixes it will immediately need again. The
radix registry fixes both, exactly like an image registry: one node per
page-aligned block keyed by chained digest, so variants SHARE their
family's ancestor blocks; and eviction under pressure SPILLS refcount-0
nodes to a host-RAM store, from which the next match pulls them back by
digest instead of re-prefilling.

Measured at EQUAL KV HBM (same tight page pool) against ``--paged``
without the registry, on the same M x K x R trace:

  * **prefill-token reduction**: must hold fig9's >= 1.3x acceptance bar
    even though no two variants declare the same prefix -- the saving now
    comes from ancestor sharing, with ancestor/partial hits accounted
    separately from whole-prefix hits;
  * **tier traffic**: the trace forces at least one spill -> restore round
    trip (a layer re-pulled from the host store under pool pressure);
  * **exactness**: request tokens are bitwise identical registry-on vs
    off.

Metrics are written to ``BENCH_prefix_radix.json`` (``--smoke`` writes
``BENCH_prefix_radix_smoke.json`` so CI never clobbers the full
artifact).
"""

from __future__ import annotations

import json
import tempfile
from pathlib import Path

import numpy as np

PAGE_SIZE = 8
FAM_PAGES = 2               # system-prompt blocks per tenant family
VAR_PAGES = 1               # few-shot extension blocks per variant
FAMILIES = 3
VARIANTS = 3
PER_VARIANT = 2             # requests per (family, variant)
TAIL = 6                    # private prompt tail (max)
GEN = 16
SLOTS = 4
N_PAGES = 14                # tight pool: registry families cannot all stay
N_PAGES_SMOKE = 11          # scaled to the smaller smoke trace
SPAN = 96                   # per-request page-table ceiling

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""


def _trace(vocab, families, variants, per_variant, gen):
    """M x K x R multi-tenant trace: family f's system prompt is FAM_PAGES
    blocks, variant v stacks VAR_PAGES few-shot blocks on it, and each
    request declares the family+variant span as its prefix. Emitted
    round-robin (variant-major) so a variant's first request arrives when
    only its ANCESTORS are registered -- ancestor hits -- and a family's
    later requests arrive after other tenants pressured its pages out --
    spill-tier restores. Later passes vary the DECLARED length: some
    requests declare a mid-block or sub-block prefix, exercising the
    front-partial merge (a registered block byte-matching past the
    declared span). Regenerated per run (GenRequests are stateful)."""
    from repro.launch.serve import _tail_budgets
    from repro.orchestrator import GenRequest
    rng = np.random.default_rng(0)
    fam = [rng.integers(0, vocab, FAM_PAGES * PAGE_SIZE)
           for _ in range(families)]
    var = [[rng.integers(0, vocab, VAR_PAGES * PAGE_SIZE)
            for _ in range(variants)] for _ in range(families)]
    n = families * variants * per_variant
    budgets = _tail_budgets(gen, n)
    reqs = []
    for r in range(per_variant):
        for v in range(variants):
            for f in range(families):
                i = len(reqs)
                shared = np.concatenate([fam[f], var[f][v]])
                if r == 0 or i % 3 == 0:
                    declared = len(shared)  # first pass registers chains
                elif i % 3 == 1:
                    # mid-block into the variant: ancestor blocks shared,
                    # front-partial merge of the declared half-block
                    declared = FAM_PAGES * PAGE_SIZE + PAGE_SIZE // 2
                else:
                    declared = PAGE_SIZE // 2   # sub-block: partial-only
                tail = rng.integers(0, vocab, 3 + (i * 2) % TAIL)
                reqs.append(GenRequest(
                    rid=i, prompt=np.concatenate([shared, tail]),
                    max_new_tokens=budgets[i], prefix_len=declared))
    return reqs


def _drive(pod, reqs, max_ticks=30_000):
    """Run to completion tracking peak concurrent admitted requests."""
    from repro.orchestrator import ContinuousScheduler
    sched = ContinuousScheduler(pod, fairness_cap=32)
    sched.submit(reqs)
    peak = 0
    while sched.busy and sched.tick < max_ticks:
        pre = sum(len(e.active) for e in pod.engines)
        adm0 = len(sched.admission_order)
        sched.step()
        peak = max(peak, pre + len(sched.admission_order) - adm0)
    return peak


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    from repro.core.runtime import Runtime
    from repro.orchestrator import Pod

    families = 2 if smoke else FAMILIES
    variants = 2 if smoke else VARIANTS
    per_variant = PER_VARIANT
    gen = 8 if smoke else GEN
    n_pages = N_PAGES_SMOKE if smoke else N_PAGES

    rt = Runtime(tempfile.mkdtemp(prefix="stevedore-fig11-"))
    rt.build(IMAGEFILE, tag="bench")

    runs = {}
    for radix in (False, True):
        pod = Pod(rt, "bench", replicas=1, n_slots=SLOTS, max_len=SPAN,
                  paged=True, page_size=PAGE_SIZE, n_pages=n_pages,
                  prefix_cache=radix, spill_pages=None if radix else 0)
        vocab = pod.engines[0].container.arch.vocab_size
        reqs = _trace(vocab, families, variants, per_variant, gen)
        peak = _drive(pod, reqs)
        eng = pod.engines[0]
        eng.pool.check()            # registry + allocator clean at the end
        assert all(r.state == "done" for r in reqs), "trace dropped work"
        from repro.orchestrator.obs import decomposition
        reg = eng.pool.status()["registry"]
        runs[radix] = {
            "peak_concurrent": peak,
            "prefill_positions": eng.prefill_positions,
            "prefix_hits": eng.prefix_hits,
            "ancestor_hits": eng.prefix_ancestor_hits,
            "partial_hits": eng.prefix_partial_hits,
            "prefix_tokens_saved": eng.prefix_tokens_saved,
            "registry_nodes": reg["nodes"],
            "registry_max_depth": reg["max_depth"],
            "spills": reg["spills"],
            "restores": reg["restores"],
            "peak_pages_in_use": eng.pool.peak_in_use,
            **decomposition([pod.trace]),
            "tokens": {r.rid: list(r.tokens) for r in reqs},
        }

    on, off = runs[True], runs[False]
    parity = off["tokens"] == on["tokens"]
    reduction = (off["prefill_positions"]
                 / max(on["prefill_positions"], 1))
    # the acceptance bars FAIL the run (and the CI smoke step); they are
    # not just fields in the artifact nothing reads
    assert parity, "request tokens differ registry-on vs registry-off"
    assert reduction >= 1.3, \
        f"prefill-token reduction {reduction:.2f}x below fig9's 1.3x bar"
    assert on["ancestor_hits"] >= 1, \
        "no ancestor hits: variants never shared their family's blocks"
    assert on["partial_hits"] >= 1, \
        "no partial hits: sub-block declarations never front-merged"
    assert on["spills"] >= 1 and on["restores"] >= 1, \
        "no spill->restore round trip: the pool never exercised the tier"

    payload = {
        "arch": "llama3.2-3b-smoke",
        "smoke": smoke,
        "page_size": PAGE_SIZE,
        "pool_pages": n_pages - 1,
        "families": families,
        "variants_per_family": variants,
        "requests_per_variant": per_variant,
        "gen_max": gen,
        "radix_off": {k: v for k, v in off.items() if k != "tokens"},
        "radix_on": {k: v for k, v in on.items() if k != "tokens"},
        "prefill_token_reduction_x": reduction,
        "token_parity_on_vs_off": parity,
    }
    out = ("BENCH_prefix_radix_smoke.json" if smoke
           else "BENCH_prefix_radix.json")
    Path(out).write_text(json.dumps(payload, indent=2))

    n = families * variants * per_variant
    return [
        ("fig11/prefill_positions_off", float(off["prefill_positions"]),
         f"{n} reqs x {families} families x {variants} variants"),
        ("fig11/prefill_positions_on", float(on["prefill_positions"]),
         f"{on['prefix_hits']} hits ({on['ancestor_hits']} ancestor, "
         f"{on['partial_hits']} partial)"),
        ("fig11/prefill_token_reduction_x", reduction,
         ">= fig9's 1.3x bar, no two variants share a declared prefix"),
        ("fig11/ancestor_hits", float(on["ancestor_hits"]),
         "k complete blocks matched below the declared span"),
        ("fig11/spills", float(on["spills"]),
         "refcount-0 pages pushed to the host tier under pressure"),
        ("fig11/restores", float(on["restores"]),
         "registry pulls: spilled layers re-materialized by digest"),
        ("fig11/registry_nodes", float(on["registry_nodes"]),
         f"radix nodes at end, depth {on['registry_max_depth']}"),
        ("fig11/peak_concurrent_on", float(on["peak_concurrent"]),
         f"vs {off['peak_concurrent']} registry-off, same pool"),
        ("fig11/token_parity_on_vs_off", float(parity),
         "bitwise-identical request tokens"),
    ]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace (CI)")
    a = ap.parse_args()
    for name, value, derived in run(smoke=a.smoke):
        print(f"{name},{value:.3f},{derived}")
