"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig2,fig4]

Prints ``name,value,derived`` CSV rows (value unit embedded in the name).
fig3 consumes/produces dry-run artifacts under results/dryrun (lowering the
missing ones in a 512-device subprocess); everything else runs live here.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else [
        "fig2_parity", "fig3_collective_abi", "fig4_import_problem",
        "fig5_tuned_kernel", "fig6_serving", "fig7_paged_kv",
        "fig9_prefix_cache", "fig10_slo", "fig12_fabric",
        "roofline_summary",
    ]
    failed = 0
    for name in names:
        short = name.split("_")[0]
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row_name, value, derived in mod.run():
                print(f"{row_name},{value:.3f},{derived}")
        except Exception:
            failed += 1
            print(f"{short}/ERROR,0,{traceback.format_exc(limit=2)!r}",
                  file=sys.stderr)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
