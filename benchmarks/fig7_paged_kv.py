"""Fig. 7 (new): paged KV-cache capacity vs contiguous per-slot slabs.

The paged-attention claim, measured AT EQUAL KV-cache HBM: a contiguous
engine pins an (n_slots, max_len) slab whether or not requests use it, so
its admitted concurrency is exactly ``n_slots``; a paged engine carving
the same bytes into a shared page pool admits requests against their
actual worst-case footprint (ceil((prompt+gen+chunk)/page_size) pages), so
a realistic heavy-tailed trace packs >= 1.5x more concurrent requests into
the same memory. A long request whose prompt+gen exceeds the contiguous
``max_len`` is also replayed on both: the slab engine rejects it, the
paged engine completes it from the same pool.

Capacity is measured in *admitted concurrent requests* (peak over ticks)
-- a scheduling-policy metric, deliberately hardware-independent, so the
benchmark runs on the smoke arch in seconds.

Metrics (also written to ``BENCH_paged.json``):
  * peak concurrent admitted requests, contiguous vs paged;
  * admitted-capacity gain (the >= 1.5x acceptance bar);
  * pool peak page occupancy + the long-request outcome on both engines.
"""

from __future__ import annotations

import json
import tempfile
from pathlib import Path

import numpy as np

SLOTS_CONTIG = 8
MAX_LEN_CONTIG = 104
PAGE_SIZE = 16
# equal HBM: pool KV positions == the contiguous bank's, + the garbage page
N_PAGES = SLOTS_CONTIG * MAX_LEN_CONTIG // PAGE_SIZE + 1
SLOTS_PAGED = 24            # slots are host bookkeeping; pages are the budget
SPAN_PAGED = 256            # per-request ceiling (page-table width), not HBM
PROMPT = 24
GEN = 64
REQUESTS = 48
LONG_PROMPT, LONG_GEN = 40, 80    # total 120 > MAX_LEN_CONTIG

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""


def _trace(rng, vocab):
    """Same heavy-tailed budget shape as fig6 (launch.serve._tail_budgets),
    offered all at tick 0 so admission pressure -- not arrival stagger --
    is what limits concurrency."""
    from repro.launch.serve import _tail_budgets
    from repro.orchestrator import GenRequest
    budgets = _tail_budgets(GEN, REQUESTS)
    reqs = [GenRequest(rid=i, prompt=rng.integers(0, vocab, PROMPT),
                       max_new_tokens=budgets[i])
            for i in range(REQUESTS)]
    reqs.append(GenRequest(rid=REQUESTS,
                           prompt=rng.integers(0, vocab, LONG_PROMPT),
                           max_new_tokens=LONG_GEN))
    return reqs


def _drive(pod, reqs, max_ticks=20_000):
    """Run to completion, tracking peak concurrent admitted requests.

    fairness_cap is set above the slot count so admission is limited by
    CAPACITY (slots / pool pressure), not by the per-tick prefill cap --
    this is a packing measurement, not a latency one."""
    from repro.orchestrator import ContinuousScheduler
    sched = ContinuousScheduler(pod, fairness_cap=32)
    sched.submit(reqs)
    peak = 0
    while sched.busy and sched.tick < max_ticks:
        pre = sum(len(e.active) for e in pod.engines)
        adm0 = len(sched.admission_order)
        sched.step()
        # post-ADMISSION residency: everything counted here held KV (slab
        # or page reservation) simultaneously, before this tick's decode
        # retired the short requests
        peak = max(peak, pre + len(sched.admission_order) - adm0)
    return sched, peak


def run() -> list[tuple[str, float, str]]:
    from repro.core.runtime import Runtime
    from repro.orchestrator import Pod

    rt = Runtime(tempfile.mkdtemp(prefix="stevedore-fig7-"))
    rt.build(IMAGEFILE, tag="bench")

    pod_c = Pod(rt, "bench", replicas=1, n_slots=SLOTS_CONTIG,
                max_len=MAX_LEN_CONTIG)
    vocab = pod_c.engines[0].container.arch.vocab_size
    reqs_c = _trace(np.random.default_rng(0), vocab)
    sched_c, peak_c = _drive(pod_c, reqs_c)

    pod_p = Pod(rt, "bench", replicas=1, n_slots=SLOTS_PAGED,
                max_len=SPAN_PAGED, paged=True, page_size=PAGE_SIZE,
                n_pages=N_PAGES)
    reqs_p = _trace(np.random.default_rng(0), vocab)
    sched_p, peak_p = _drive(pod_p, reqs_p)
    pool = pod_p.engines[0].pool
    pool.check()                     # allocator left clean after a full trace

    long_c, long_p = reqs_c[-1], reqs_p[-1]
    done_p = sum(r.state == "done" for r in reqs_p)
    done_c = sum(r.state == "done" for r in reqs_c)
    gain = peak_p / max(peak_c, 1)
    kv_positions = (N_PAGES - 1) * PAGE_SIZE

    payload = {
        "arch": "llama3.2-3b-smoke",
        "kv_positions_both": kv_positions,
        "page_size": PAGE_SIZE,
        "contiguous": {"slots": SLOTS_CONTIG, "max_len": MAX_LEN_CONTIG,
                       "peak_concurrent": peak_c, "completed": done_c,
                       "long_request": long_c.state,
                       "long_request_error": long_c.error},
        "paged": {"slots": SLOTS_PAGED, "span": SPAN_PAGED,
                  "pool_pages": N_PAGES - 1,
                  "peak_concurrent": peak_p, "completed": done_p,
                  "peak_pages_in_use": pool.peak_in_use,
                  "long_request": long_p.state,
                  "long_request_tokens": len(long_p.tokens)},
        "admitted_capacity_gain_x": gain,
    }
    Path("BENCH_paged.json").write_text(json.dumps(payload, indent=2))

    return [
        ("fig7/contiguous_peak_concurrent", float(peak_c),
         f"{SLOTS_CONTIG} slots x {MAX_LEN_CONTIG}"),
        ("fig7/paged_peak_concurrent", float(peak_p),
         f"{N_PAGES - 1} pages x {PAGE_SIZE} (equal HBM)"),
        ("fig7/admitted_capacity_gain_x", gain,
         "paged vs contiguous at equal KV-cache HBM"),
        ("fig7/paged_peak_pages_in_use", float(pool.peak_in_use),
         f"of {N_PAGES - 1}"),
        ("fig7/long_request_completed_paged",
         float(long_p.state == "done" and long_c.state == "rejected"),
         f"prompt+gen {LONG_PROMPT + LONG_GEN} vs slab {MAX_LEN_CONTIG}"),
    ]


if __name__ == "__main__":
    for name, value, derived in run():
        print(f"{name},{value:.3f},{derived}")
