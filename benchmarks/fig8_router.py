"""Fig. 8 (new): admitted-throughput scaling across a multi-pod router.

The router-tier claim, measured two ways on the SAME heavy-tailed trace
the other serving figures replay (launch.serve._tail_budgets):

1. **Scaling**: a PodRouter fronting P pods (each one replica of SLOTS KV
   slots) serves a saturating trace; fleet throughput is *useful tokens
   per router tick* -- one router tick steps every pod once, i.e. the
   lockstep abstraction of P hosts decoding concurrently, so the metric
   is hardware-independent and CI-stable. The acceptance bar: >= 1.7x
   from 1 pod to 2, monotone through 4.

2. **Rolling fleet upgrade under load**: re-point the tag mid-trace and
   roll a 3-pod fleet pod-by-pod. Every drain tick goes through
   ``router.step``, so the non-rolling pods keep admitting and decoding;
   the bar is ZERO dropped/killed/rejected requests (every request
   finishes with its exact token budget), completions observed during the
   upgrade window, and fleet capacity never below N-1 pods.

Metrics are also written to ``BENCH_router.json``. ``--smoke`` shrinks
the trace and scaling sweep for the CI smoke invocation -- below
saturation, so the 1.7x bar is evaluated on the FULL run only (the smoke
run just exercises the routing + upgrade paths end-to-end, and writes
``BENCH_router_smoke.json`` so it never clobbers the full artifact).
"""

from __future__ import annotations

import json
import tempfile
from pathlib import Path

import numpy as np

SLOTS = 4           # per pod (one replica each): pods are the scaling axis
GEN = 32
REQUESTS = 96
ARRIVE_PER_TICK = 16
UPGRADE_PODS = 3

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""


def _trace(rng, vocab, n, gen, arrive_per_tick=ARRIVE_PER_TICK, base_rid=0):
    """The shared heavy-tailed trace (fig6/fig7 budgets), staggered fast
    enough to saturate the largest fleet -- admission pressure, not
    arrival starvation, is what the scaling sweep measures."""
    from repro.launch.serve import _tail_budgets
    from repro.orchestrator import GenRequest
    budgets = _tail_budgets(gen, n)
    return [GenRequest(rid=base_rid + i,
                       prompt=rng.integers(0, vocab, 8 + (i * 5) % 17),
                       max_new_tokens=budgets[i],
                       arrival=i // arrive_per_tick)
            for i in range(n)]


def _fleet(rt, n_pods, max_len):
    from repro.orchestrator import Pod, PodRouter
    pods = [Pod(rt, "bench", replicas=1, n_slots=SLOTS, max_len=max_len)
            for _ in range(n_pods)]
    return PodRouter(pods, policy="shortest-queue", fairness_cap=8)


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    from repro.core.runtime import Runtime

    n_requests = 24 if smoke else REQUESTS
    gen = 16 if smoke else GEN
    sweep = (1, 2) if smoke else (1, 2, 3, 4)
    max_len = 8 + 16 + gen + 8          # longest prompt + budget + chunk

    rt = Runtime(tempfile.mkdtemp(prefix="stevedore-fig8-"))
    rt.build(IMAGEFILE, tag="bench")

    # -- scaling sweep -------------------------------------------------------
    from repro.orchestrator.obs import decomposition
    from repro.orchestrator.telemetry import latency_summary
    scaling = []
    vocab = None
    for n_pods in sweep:
        router = _fleet(rt, n_pods, max_len)
        if vocab is None:
            vocab = router.pods[0].engines[0].container.arch.vocab_size
        reqs = _trace(np.random.default_rng(0), vocab, n_requests, gen)
        router.submit(reqs)
        router.run(max_ticks=100_000)
        assert all(r.state == "done" for r in reqs), "scaling trace dropped work"
        tokens = sum(len(r.tokens) for r in reqs)
        ticks = router.tick
        scaling.append({"pods": n_pods, "tokens": tokens,
                        "router_ticks": ticks,
                        "tok_per_tick": tokens / max(ticks, 1),
                        # nearest-rank, same definition as serve.py/fig6
                        **latency_summary(reqs),
                        # TTFT/ITL from the fleet's span logs, not re-derived
                        **decomposition(router.trace_buffers())})
    tpt = {s["pods"]: s["tok_per_tick"] for s in scaling}
    speedup_2x = tpt[2] / max(tpt[1], 1e-9)
    monotone = all(scaling[i]["tok_per_tick"] <= scaling[i + 1]["tok_per_tick"]
                   for i in range(len(scaling) - 1))

    # -- rolling fleet upgrade under sustained load --------------------------
    from repro.orchestrator import RollingDeployer
    router = _fleet(rt, UPGRADE_PODS, max_len)
    rng = np.random.default_rng(1)
    # sustained: long budgets + arrivals that keep trickling in across the
    # whole upgrade window
    load = _trace(rng, vocab, n_requests // 2, gen,
                  arrive_per_tick=4, base_rid=1000)
    for r in load:
        r.max_new_tokens = max(r.max_new_tokens, gen // 2)
    router.submit(load)
    for _ in range(3):                  # get real work in flight first
        router.step()
    in_flight = sum(len(e.active) for p in router.pods for e in p.engines)

    rt.build(IMAGEFILE + "LABEL release=r2\n", tag="bench")
    done_before = len(router.completed)
    report = RollingDeployer(router).upgrade()
    served_during = len(router.completed) - done_before
    router.run(max_ticks=100_000)

    dropped = sum(r.state != "done" or len(r.tokens) != r.max_new_tokens
                  for r in load)
    new_digest = rt.registry.resolve("bench")
    swapped = all(e.container.image.digest == new_digest
                  for p in router.pods for e in p.engines)
    floor = report["capacity_floor"] or 0
    n1_capacity = (UPGRADE_PODS - 1) * SLOTS

    payload = {
        "arch": "llama3.2-3b-smoke",
        "smoke": smoke,
        "slots_per_pod": SLOTS,
        "requests": n_requests,
        "gen_max": gen,
        "scaling": scaling,
        "admitted_tok_per_tick_speedup_1_to_2": speedup_2x,
        "scaling_monotone": monotone,
        "upgrade": {
            "pods": UPGRADE_PODS,
            "in_flight_at_start": in_flight,
            "completed_during_upgrade": served_during,
            "capacity_floor": floor,
            "n_minus_1_capacity": n1_capacity,
            "dropped_or_killed": dropped,
            "all_replicas_on_new_digest": swapped,
        },
    }
    # smoke runs are below saturation: write them to a side file so the CI
    # invocation never clobbers the committed full-run acceptance artifact
    out = "BENCH_router_smoke.json" if smoke else "BENCH_router.json"
    Path(out).write_text(json.dumps(payload, indent=2))

    return [
        ("fig8/tok_per_tick_1pod", tpt[1], f"{SLOTS} slots"),
        ("fig8/tok_per_tick_2pods", tpt[2], f"2x{SLOTS} slots via router"),
        ("fig8/admitted_speedup_1_to_2", speedup_2x, ">= 1.7x bar"),
        ("fig8/scaling_monotone", float(monotone),
         "tok/tick nondecreasing " + "->".join(str(s) for s in sweep)),
        ("fig8/upgrade_dropped_requests", float(dropped), "bar: 0"),
        ("fig8/upgrade_capacity_floor", float(floor),
         f">= N-1 pods = {n1_capacity} slots"),
        ("fig8/upgrade_served_during_roll", float(served_during),
         "non-rolling pods kept serving"),
        ("fig8/p99_latency_ticks_max_pods", float(
            scaling[-1]["p99_latency_ticks"]),
         f"nearest-rank, {sweep[-1]} pods"),
        ("fig8/ttft_p99_ticks_max_pods", float(
            scaling[-1]["ttft_p99_ticks"]),
         f"time-to-first-token, {sweep[-1]} pods (from spans)"),
        ("fig8/itl_p50_ticks_max_pods", float(
            scaling[-1]["itl_p50_ticks"]),
         f"inter-token latency, {sweep[-1]} pods (from spans)"),
    ]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace + 1->2 pod sweep (CI)")
    a = ap.parse_args()
    for name, value, derived in run(smoke=a.smoke):
        print(f"{name},{value:.3f},{derived}")
